"""Core transformer layers: norms, RoPE, attention (dense / blockwise / decode),
dense FFN. Pure functions over param dicts; sharding via ShardCtx constraints."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.config import ModelConfig
from repro.models.params import Spec

_NEG_INF = -1e30


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rmsnorm_specs(d_model: int):
    # stored as (weight - 1) so zeros-init == identity (gemma convention);
    # rmsnorm() adds the 1 back.
    return Spec((d_model,), ("embed",), init="zeros")


# ----------------------------------------------------------------------------
# Positional embeddings
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    half = x.shape[-1] // 2
    freqs = jnp.asarray(rope_frequencies(x.shape[-1], theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    specs = {
        "wq": Spec((d, cfg.num_heads, cfg.head_dim), ("embed", "heads", "head_dim")),
        "wk": Spec((d, cfg.num_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, cfg.num_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((cfg.num_heads, cfg.head_dim, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = Spec((cfg.num_heads, cfg.head_dim), ("heads", "head_dim"), init="zeros")
        specs["bk"] = Spec((cfg.num_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = Spec((cfg.num_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), init="zeros")
    if cross:
        specs["attn_gate"] = Spec((), (), init="zeros")
        specs["q_norm"] = rmsnorm_specs(cfg.head_dim * 0 + cfg.head_dim)
        specs["k_norm"] = rmsnorm_specs(cfg.head_dim)
    return specs


def _project_qkv(p, x, cfg: ModelConfig, ctx: ShardCtx, kv_input=None):
    kv_src = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = ctx.c(q, "batch", "seq", "heads", "head_dim")
    k = ctx.c(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.c(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _ragged_block_kv(span: int, cap: int = 128) -> int:
    """Largest power-of-two KV block <= cap that tiles the cache span (the
    ragged decode kernel requires span % block_kv == 0)."""
    b = 1
    while b * 2 <= min(span, cap) and span % (b * 2) == 0:
        b *= 2
    return b


def _group_query(q, num_kv_heads: int):
    """[B,S,Hq,D] -> [B,S,Hkv,G,D] grouping query heads per KV head."""
    b, s, hq, d = q.shape
    g = hq // num_kv_heads
    return q.reshape(b, s, num_kv_heads, g, d)


def _softmax_fp32(scores, axis=-1):
    m = jnp.max(scores, axis=axis, keepdims=True)
    e = jnp.exp(scores - lax.stop_gradient(m))
    return e / jnp.sum(e, axis=axis, keepdims=True)


def _expand_kv(k, hq: int):
    """Repeat KV heads to the full query-head count. Keeps the score einsum
    a plain MHA dot whose head dim shards cleanly over the model axis even
    when kv_heads < mesh model size (GQA-TP practice; negligible FLOPs)."""
    hkv = k.shape[2]
    if hkv == hq:
        return k
    g = hq // hkv
    b, s, _, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, g, d)
                            ).reshape(b, s, hq, d)


def dense_attention(q, k, v, *, causal: bool, window: Optional[int],
                    q_offset=0, softcap: Optional[float] = None,
                    kv_len_mask=None):
    """Reference-quality attention materializing the score matrix.

    q: [B,Sq,Hq,D], k/v: [B,Skv,Hkv,D]. Used for seq <= attn_dense_max_seq.
    """
    b, sq, hq, d = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    if kv_len_mask is not None:                              # [B,Skv] bool
        scores = jnp.where(kv_len_mask[:, None, None, :], scores, _NEG_INF)
    probs = _softmax_fp32(scores).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def blockwise_attention(q, k, v, *, causal: bool, window: Optional[int],
                        block_q: int, block_kv: int, ctx: ShardCtx = NULL_CTX):
    """Flash-style blockwise causal attention with online softmax.

    Memory-bounded (never materializes [Sq,Skv]); compact HLO (scan over q
    blocks, nested scan over kv blocks). Masked blocks are still *computed*
    (static shapes) — the Pallas kernel skips them on real hardware; the HLO
    roofline notes this 2x.
    """
    b, s, hq, d = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    nq, nkv = s // block_q, s // block_kv
    scale = 1.0 / np.sqrt(d)

    qb = q.reshape(b, nq, block_q, hq, d)
    kb = k.reshape(b, nkv, block_kv, hq, d)
    vb = v.reshape(b, nkv, block_kv, hq, d)

    qb = jnp.moveaxis(qb, 1, 0)      # [nq, b, bq, h, d]
    kb = jnp.moveaxis(kb, 1, 0)
    vb = jnp.moveaxis(vb, 1, 0)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk

        def kv_step(carry, kv):
            m, l, acc = carry
            kj, kblk, vblk = kv
            scores = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk)
            scores = scores.astype(jnp.float32) * scale
            qpos = qi * block_q + jnp.arange(block_q)
            kpos = kj * block_kv + jnp.arange(block_kv)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            scores = jnp.where(mask[None, None], scores, _NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, block_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, block_q), jnp.float32)
        a0 = jnp.zeros((b, hq, block_q, d), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_step, None, (jnp.arange(nq), qb))
    # outs: [nq, b, h, bq, d] -> [b, s, h, d]
    outs = jnp.moveaxis(outs, 0, 2)                      # b, h, nq, bq, d
    outs = outs.reshape(b, hq, s, d)
    return jnp.moveaxis(outs, 1, 2)


def decode_attention(q, k_cache, v_cache, kv_lens, *, window: Optional[int],
                     ctx: ShardCtx = NULL_CTX, layout: str = "bshd"):
    """Single-token attention against a (possibly padded) KV cache.

    q: [B,1,Hq,D]; caches: [B,Smax,Hkv,D] ("bshd") or [B,Hkv,Smax,D]
    ("bhsd", head-major: the dots read the cache with no transposes);
    kv_lens: [B] number of valid entries. kv_seq may be sharded over the
    model axis — XLA inserts the partial-softmax collectives
    (flash-decoding pattern).
    """
    b, _, hq, d = q.shape
    hkv = k_cache.shape[1] if layout == "bhsd" else k_cache.shape[2]
    smax = k_cache.shape[2] if layout == "bhsd" else k_cache.shape[1]
    qg = _group_query(q, hkv)[:, 0]                          # [B,Hkv,G,D]
    scale = 1.0 / np.sqrt(d)
    if layout == "bhsd":
        scores = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache)
    else:
        scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache)
    scores = scores.astype(jnp.float32) * scale
    kpos = jnp.arange(smax)
    mask = kpos[None, :] < kv_lens[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] >= kv_lens[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, _NEG_INF)
    probs = _softmax_fp32(scores).astype(v_cache.dtype)
    if layout == "bhsd":
        out = jnp.einsum("bhgk,bhkd->bhgd", probs, v_cache)
    else:
        out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache)
    return out.reshape(b, 1, hq, d)


def attention_block(p, x, cfg: ModelConfig, ctx: ShardCtx, *,
                    positions, cache=None, kv_lens=None, cross_kv=None):
    """Full attention mixer. Returns (out, new_cache_entry).

    cache: dict(k=[B,Smax,Hkv,D], v=...) or None (full-sequence mode).
    """
    is_cross = cross_kv is not None
    q, k, v = _project_qkv(p, x, cfg, ctx, kv_input=cross_kv)
    if cfg.pos_embedding == "rope" and not is_cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not is_cross:
        # decode: write this step's k/v at position kv_lens, then attend.
        k_cache, v_cache = cache["k"], cache["v"]
        hm = cfg.cache_layout == "bhsd"      # head-major cache
        cache_ax = (("batch", "kv_heads", "kv_seq", "head_dim") if hm
                    else ("batch", "kv_seq", "kv_heads", "head_dim"))
        span = k_cache.shape[2] if hm else k_cache.shape[1]
        if x.shape[1] == 1:
            # ring-buffer slot when a sliding window bounds the cache span
            slot = kv_lens % span
            mode = cfg.decode_cache_update
            k_new = k.transpose(0, 2, 1, 3) if hm else k    # [B,H,1,D] | [B,1,H,D]
            v_new = v.transpose(0, 2, 1, 3) if hm else v
            if mode == "uniform":
                # static-bucket serving: every slot is at the same position
                pos = slot[0]
                start = (0, 0, pos, 0) if hm else (0, pos, 0, 0)
                k_cache = lax.dynamic_update_slice(
                    k_cache, k_new.astype(k_cache.dtype), start)
                v_cache = lax.dynamic_update_slice(
                    v_cache, v_new.astype(v_cache.dtype), start)
            elif mode == "scatter":
                bidx = jnp.arange(k.shape[0])
                if hm:
                    k_cache = k_cache.at[bidx, :, slot].set(
                        k_new[:, :, 0].astype(k_cache.dtype))
                    v_cache = v_cache.at[bidx, :, slot].set(
                        v_new[:, :, 0].astype(v_cache.dtype))
                else:
                    k_cache = k_cache.at[bidx, slot].set(
                        k[:, 0].astype(k_cache.dtype))
                    v_cache = v_cache.at[bidx, slot].set(
                        v[:, 0].astype(v_cache.dtype))
            else:  # onehot (baseline): full-cache read-modify-write
                oh = (jnp.arange(span)[None, :] ==
                      slot[:, None]).astype(k_cache.dtype)
                oh = oh[:, None, :, None] if hm else oh[:, :, None, None]
                k_cache = k_cache * (1 - oh) + oh * k_new.astype(k_cache.dtype)
                v_cache = v_cache * (1 - oh) + oh * v_new.astype(v_cache.dtype)
            k_cache = ctx.c(k_cache, *cache_ax)
            v_cache = ctx.c(v_cache, *cache_ax)
            valid = jnp.minimum(kv_lens + 1, span)
            # ring buffer holds the most recent `valid` tokens; absolute RoPE
            # was applied before caching so slot order is irrelevant.
            if cfg.resolved_decode_attention_impl == "ragged" and not hm:
                # per-request early exit over KV blocks (elastic batching at
                # the kernel level): a short request only pays its own span;
                # interpret mode resolves via kernels.default_interpret
                from repro.kernels.ragged_decode_attention.ops import (
                    ragged_decode_attention)
                out = ragged_decode_attention(
                    q[:, 0], k_cache, v_cache, valid,
                    block_kv=_ragged_block_kv(span))[:, None]
            else:
                out = decode_attention(q, k_cache, v_cache, valid,
                                       window=None, ctx=ctx,
                                       layout=cfg.cache_layout)
        else:
            # prefill: attend within the prompt, then store the (windowed)
            # tail of k/v into the cache.
            out = _self_attention_full(q, k, v, cfg, ctx)
            k_in, v_in = k, v
            if k.shape[1] > span:
                k_in, v_in = k[:, -span:], v[:, -span:]
            if hm:
                k_in = k_in.transpose(0, 2, 1, 3)
                v_in = v_in.transpose(0, 2, 1, 3)
            k_cache = lax.dynamic_update_slice(
                k_cache, k_in.astype(k_cache.dtype), (0, 0, 0, 0))
            v_cache = lax.dynamic_update_slice(
                v_cache, v_in.astype(v_cache.dtype), (0, 0, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
    elif is_cross:
        if "q_norm" in p:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        out = dense_attention(q, k, v, causal=False, window=None)
    else:
        out = _self_attention_full(q, k, v, cfg, ctx)

    out = ctx.c(out, "batch", "seq", "heads", "head_dim")
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if is_cross:
        proj = jnp.tanh(p["attn_gate"].astype(jnp.float32)).astype(proj.dtype) * proj
    return ctx.c(proj, "batch", "seq", "embed"), new_cache


def _self_attention_full(q, k, v, cfg: ModelConfig, ctx: ShardCtx):
    if q.shape[1] <= cfg.attn_dense_max_seq:
        return dense_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               softcap=cfg.attn_logit_softcap)
    return blockwise_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               block_q=cfg.attn_chunk_q,
                               block_kv=cfg.attn_chunk_kv, ctx=ctx)


# ----------------------------------------------------------------------------
# Dense FFN
# ----------------------------------------------------------------------------

def ffn_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    specs = {
        "w_up": Spec((d, f), ("embed", "ffn")),
        "w_down": Spec((f, d), ("ffn", "embed")),
    }
    if cfg.gated_ffn:
        specs["w_gate"] = Spec((d, f), ("embed", "ffn"))
    return specs


def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def ffn_block(p, x, cfg: ModelConfig, ctx: ShardCtx):
    act = _act(cfg.ffn_activation)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if cfg.gated_ffn:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    h = ctx.c(h, "batch", "seq", "ffn")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return ctx.c(out, "batch", "seq", "embed")
