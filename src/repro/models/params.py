"""Parameter specification machinery.

Models declare parameters as a pytree of ``Spec(shape, logical_axes, init)``.
From one spec tree we derive: materialized params (smoke tests / real
training), ``jax.ShapeDtypeStruct`` stand-ins with shardings (dry-run), and
NamedShardings (pjit in/out shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardCtx, make_named_sharding


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_one(spec: Spec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if spec.shape else 1
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(specs, rng, dtype=jnp.float32):
    """Materialize a spec tree into arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    arrs = [_init_one(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(specs, dtype=jnp.bfloat16, mesh=None, rules=None):
    """ShapeDtypeStructs (with shardings when a mesh is given) — no allocation."""

    def one(s: Spec):
        sharding = None
        if mesh is not None:
            sharding = make_named_sharding(mesh, s.axes, rules, s.shape)
        return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sharding)

    return jax.tree.map(one, specs, is_leaf=is_spec)


def param_shardings(specs, mesh, rules=None):
    return jax.tree.map(
        lambda s: make_named_sharding(mesh, s.axes, rules, s.shape),
        specs, is_leaf=is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_group(spec: Spec, num_groups: int) -> Spec:
    """Prepend the scanned layer-group dimension."""
    return Spec((num_groups,) + spec.shape, ("layers",) + spec.axes,
                spec.init, spec.scale)


def stack_specs(tree, num_groups: int):
    return jax.tree.map(lambda s: stack_group(s, num_groups), tree, is_leaf=is_spec)
