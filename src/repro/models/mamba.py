"""Mamba2 (state-space duality) mixer.

Implements the chunked SSD algorithm (arXiv:2405.21060) in pure JAX:
intra-chunk quadratic attention-like term + inter-chunk linear state
recurrence carried by ``lax.scan``. Single-step recurrence for decode.

Shapes: x [B,S,D] -> in_proj -> z [B,S,Din], xs [B,S,Din], B/C [B,S,G,N],
dt [B,S,H]; heads H = Din / P (P = ssm_head_dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharding import ShardCtx
from repro.models.config import ModelConfig
from repro.models.params import Spec


def mamba_specs(cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * din + 2 * g * n + h
    return {
        "in_proj": Spec((d, proj_out), ("embed", "ssm_inner")),
        "conv_w": Spec((cfg.ssm_conv_dim, cfg.ssm_conv_kernel),
                       ("conv_dim", None), scale=0.5),
        "A_log": Spec((h,), ("ssm_heads",), init="ones"),
        "D": Spec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": Spec((h,), ("ssm_heads",), init="zeros"),
        "norm_w": Spec((din,), ("ssm_inner",), init="zeros"),
        "out_proj": Spec((din, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, proj):
    din, g, n, h = cfg.ssm_d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xs = proj[..., din:2 * din]
    Bm = proj[..., 2 * din:2 * din + g * n]
    Cm = proj[..., 2 * din + g * n:2 * din + 2 * g * n]
    dt = proj[..., 2 * din + 2 * g * n:]
    return z, xs, Bm, Cm, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d. x: [B,S,C]; w: [C,K]; state: [B,K-1,C]."""
    k = w.shape[1]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # [B,S+K-1,C]
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i].astype(x.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out, new_state


def _gated_rmsnorm(y, z, weight, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    out = y32 * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(y.dtype)


def _ssd_chunked(xh, dt, A, Bm, Cm, cfg: ModelConfig, ctx, init_state=None):
    """Chunked SSD scan.

    xh: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    b, s, h, p_dim = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(cfg.ssm_chunk, s)
    orig_s = s
    if s % q:
        # pad with dt=0 tokens: zero dA and zero input weight, so they do not
        # perturb the state; their outputs are sliced away below.
        pad = q - s % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // q
    rep = h // g                                             # heads per group

    def chunk(a):
        return a.reshape((b, nc, q) + a.shape[2:])

    xh_c = chunk(xh)                                          # [B,C,Q,H,P]
    dt_c = chunk(dt)                                          # [B,C,Q,H]
    B_c = chunk(Bm)                                           # [B,C,Q,G,N]
    C_c = chunk(Cm)

    dA = dt_c * A[None, None, None, :]                        # [B,C,Q,H] (<=0)
    dA = ctx.c(dA, "batch", None, None, "ssm_heads")
    cums = jnp.cumsum(dA, axis=2)                             # within-chunk cumsum
    total = cums[:, :, -1, :]                                 # [B,C,H]

    # intra-chunk: att[i,j] = exp(cums_i - cums_j) * (C_i . B_j)  (i >= j)
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]     # [B,C,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: above-diagonal entries are positive and overflow,
    # which would poison gradients through the where (NaN x 0 = NaN).
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcigm,bcjgm->bcijg", C_c.astype(jnp.float32),
                    B_c.astype(jnp.float32))                  # [B,C,Q,Q,G]
    # broadcast groups over their heads without materializing a repeat
    bq = decay.shape
    att = (cb[..., :, None] *
           decay.reshape(bq[0], bq[1], q, q, g, rep) *
           dt_c[:, :, None, :, None, :].reshape(bq[0], bq[1], 1, q, g, rep)
           ).reshape(bq[0], bq[1], q, q, h)                   # [B,C,Q,Q,H]
    att = ctx.c(att, "batch", None, None, None, "ssm_heads")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att,
                         xh_c.astype(jnp.float32))

    # chunk states: sum_j exp(total - cums_j) dt_j x_j B_j -> [B,C,H,P,N]
    decay_to_end = jnp.exp(total[:, :, None, :] - cums)       # [B,C,Q,H]
    w = (decay_to_end * dt_c).astype(jnp.float32)
    xw = (w[..., None] * xh_c.astype(jnp.float32)             # [B,C,Q,H,P]
          ).reshape(b, nc, q, g, rep, p_dim)
    states = jnp.einsum("bcqgrp,bcqgn->bcgrpn", xw,
                        B_c.astype(jnp.float32)
                        ).reshape(b, nc, h, p_dim, n)
    states = ctx.c(states, "batch", None, "ssm_heads", None, None)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(total)                              # [B,C,H]

    def step(h_prev, inp):
        dec, st = inp                                         # [B,H], [B,H,P,N]
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev                                  # emit state *before* chunk

    h0 = (jnp.zeros((b, h, p_dim, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    hT, h_before = lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                   # [B,C,H,P,N]

    # inter-chunk contribution: C_i . (exp(cums_i) * h_before)
    hb_g = h_before.reshape(b, nc, g, rep, p_dim, n)
    y_inter = jnp.einsum("bcqgn,bcgrpn->bcqgrp", C_c.astype(jnp.float32),
                         hb_g).reshape(b, nc, q, h, p_dim)
    y_inter = y_inter * jnp.exp(cums)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, p_dim)
    return y[:, :orig_s], hT


def mamba_block(p, x, cfg: ModelConfig, ctx: ShardCtx, *, state=None):
    """Full Mamba2 mixer. state: dict(conv=[B,K-1,C], ssm=[B,H,P,N]) for decode.

    Returns (out [B,S,D], new_state or None).
    """
    b, s, d = x.shape
    h, p_dim = cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)          # [B,S,conv_dim]
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    din = cfg.ssm_d_inner
    gn = cfg.ssm_n_groups * cfg.ssm_state
    xs = conv_out[..., :din]
    Bm = conv_out[..., din:din + gn].reshape(b, s, cfg.ssm_n_groups, cfg.ssm_state)
    Cm = conv_out[..., din + gn:].reshape(b, s, cfg.ssm_n_groups, cfg.ssm_state)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [H], negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))    # [B,S,H]
    xh = xs.reshape(b, s, h, p_dim)
    xh = ctx.c(xh, "batch", "seq", "ssm_heads", None)

    if state is None or s > 1:
        ssm_init = None if state is None else state["ssm"]
        y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, cfg, ctx, init_state=ssm_init)
    else:
        # single-token recurrence: h = h*exp(dt*A) + dt * x B ; y = C.h
        h_prev = state["ssm"].astype(jnp.float32)             # [B,H,P,N]
        dt1 = dt[:, 0]                                        # [B,H]
        dec = jnp.exp(dt1 * A[None, :])
        rep = h // cfg.ssm_n_groups
        B1 = jnp.repeat(Bm[:, 0], rep, axis=1)                # [B,H,N]
        C1 = jnp.repeat(Cm[:, 0], rep, axis=1)
        xb = jnp.einsum("bhp,bhn->bhpn", xh[:, 0].astype(jnp.float32),
                        B1.astype(jnp.float32))
        hT = h_prev * dec[:, :, None, None] + dt1[:, :, None, None] * xb
        y = jnp.einsum("bhn,bhpn->bhp", C1.astype(jnp.float32), hT)[:, None]

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    out = ctx.c(out, "batch", "seq", "embed")

    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "ssm": hT.astype(state["ssm"].dtype)}
    return out, new_state
