"""Model configuration for the architecture zoo.

One ``ModelConfig`` describes any member of the assigned pool: dense GQA
transformers, MoE, SSM (Mamba2), hybrid (Jamba), VLM (cross-attention
decoder) and audio (decoder over EnCodec tokens with stub frontend).

Layer stacks are expressed as a repeating *group pattern* — a tuple of
``(mixer, ffn)`` pairs — scanned ``num_groups`` times with ``jax.lax.scan``
so the lowered HLO is layer-count independent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

MIXER_KINDS = ("attn", "mamba", "cross_attn")
FFN_KINDS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer stack: pattern of (mixer, ffn); stack = pattern * num_groups
    group_pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)

    # attention
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"      # rope | sinusoidal | none
    sliding_window: Optional[int] = None
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None

    # ffn
    ffn_activation: str = "silu"     # silu | gelu
    gated_ffn: bool = True

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # GShard-style dispatch groups: tokens are dispatched within groups so a
    # group maps to one data shard and the scatter/gather is collective-free.
    # 1 = global dispatch. Set to the batch-shard count by the launcher.
    moe_groups: int = 1

    # ssm (mamba2 / jamba)
    ssm_state: int = 0
    ssm_d_inner: int = 0
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 128

    # decode cache-update strategy:
    #   onehot  - arithmetic read-modify-write of the whole cache (baseline)
    #   scatter - per-request scatter of the new row (ragged-safe)
    #   uniform - dynamic_update_slice at kv_lens[0] (static-bucket serving:
    #             all slots share the position; cheapest)
    decode_cache_update: str = "onehot"
    # unroll the (small) decode body over layer groups with per-group cache
    # leaves: every cache update aliases in place, eliminating the scan's
    # stacked-cache writeback copies (SPerf gemma decode iteration 3)
    decode_unroll_layers: bool = False
    # KV-cache layout: "bshd" (baseline) or "bhsd" (head-major: the decode
    # attention dots read the cache directly, no per-layer transpose copies)
    cache_layout: str = "bshd"
    # decode attention implementation:
    #   auto   - ragged on TPU (the Pallas fast path is the serving
    #            default), dense elsewhere; resolved at use time via
    #            ``resolved_decode_attention_impl``.  On CPU the ragged
    #            kernel is still selectable explicitly and runs in Pallas
    #            interpret mode (kernels.default_interpret).
    #   dense  - padded softmax over the full cache span (baseline,
    #            always selectable)
    #   ragged - repro.kernels ragged decode kernel: per-request early exit
    #            over KV blocks, so early-finished slots stop paying padded
    #            KV compute. bshd layout only (bhsd keeps the dense path).
    #            block_kv is the largest power of two (<=128) dividing the
    #            cache span — non-power-of-two spans degrade toward
    #            block_kv=1, so keep max_seq a power of two.
    decode_attention_impl: str = "auto"

    # vlm
    vision_seq: int = 0              # stub patch-embedding length
    # audio
    embeddings_input: bool = False   # frontend stub feeds embeddings directly

    # embedding / head
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) scaling
    vocab_pad_to: int = 128
    norm_eps: float = 1e-5

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    use_fsdp: bool = False           # shard embed dim over data axis
    num_microbatches: int = 1        # grad-accumulation microbatches
    attn_chunk_q: int = 512          # blockwise attention q block
    attn_chunk_kv: int = 512         # blockwise attention kv block
    attn_dense_max_seq: int = 4096   # use dense attention at/below this seqlen
    logits_fp32: bool = True

    # per-arch logical->mesh sharding rule overrides (e.g. mixtral's 8
    # experts don't divide the 16-way model axis, so its expert FFN dim
    # shards instead). Tuple of (logical, axis) pairs (hashable).
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    # expected parameter count from the source (for MODEL_FLOPS accounting);
    # 0 means "use the exact computed count".
    expected_params: int = 0

    def __post_init__(self):
        assert self.num_layers % len(self.group_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern length {len(self.group_pattern)}")
        for mixer, ffn in self.group_pattern:
            assert mixer in MIXER_KINDS and ffn in FFN_KINDS

    # ---------------- derived properties ----------------

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.group_pattern)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_heads(self) -> int:
        if not self.ssm_d_inner:
            return 0
        assert self.ssm_d_inner % self.ssm_head_dim == 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_conv_dim(self) -> int:
        return self.ssm_d_inner + 2 * self.ssm_n_groups * self.ssm_state

    @property
    def resolved_decode_attention_impl(self) -> str:
        """``decode_attention_impl`` with ``"auto"`` resolved for the
        current backend: the ragged Pallas decode kernel is the default on
        TPU (benchmarked in ``benchmarks/bench_scale.py``; docs/performance.md),
        dense everywhere else.  Explicit ``"dense"``/``"ragged"`` always
        win — dense stays selectable on TPU and ragged runs in interpret
        mode on CPU."""
        if self.decode_attention_impl != "auto":
            return self.decode_attention_impl
        import jax
        return "ragged" if jax.default_backend() == "tpu" else "dense"

    @property
    def has_attention(self) -> bool:
        return any(m in ("attn", "cross_attn") for m, _ in self.group_pattern)

    @property
    def subquadratic(self) -> bool:
        """True when decode-time context cost is sub-quadratic: SSM/hybrid
        stacks, or attention bounded by a sliding window."""
        if not self.has_attention:
            return True
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    # ---------------- parameter accounting ----------------

    def _layer_params(self, mixer: str, ffn: str) -> int:
        d = self.d_model
        n = 0
        if mixer == "attn" or mixer == "cross_attn":
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                n += self.q_dim + 2 * self.kv_dim
            n += 2 * d  # input norms (pre-mixer, pre-ffn)
            if mixer == "cross_attn":
                n += 2                      # attn + ffn tanh gates
                n += 2 * self.head_dim      # q/k norms
        elif mixer == "mamba":
            din = self.ssm_d_inner
            proj_out = 2 * din + 2 * self.ssm_n_groups * self.ssm_state + self.ssm_heads
            n += d * proj_out                       # in_proj
            n += self.ssm_conv_dim * self.ssm_conv_kernel
            n += 3 * self.ssm_heads                 # A_log, D, dt_bias
            n += din                                # gated norm
            n += din * d                            # out_proj
            n += d                                  # pre-mixer norm
            if ffn != "none":
                n += d
        if ffn == "dense":
            mult = 3 if self.gated_ffn else 2
            n += mult * d * self.d_ff
        elif ffn == "moe":
            mult = 3 if self.gated_ffn else 2
            n += self.num_experts * mult * d * self.moe_d_ff
            n += d * self.num_experts               # router
            if self.num_shared_experts:
                n += self.num_shared_experts * mult * d * self.moe_d_ff
        return n

    def param_count(self) -> int:
        n = self.padded_vocab * self.d_model        # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model   # lm head
        n += self.d_model                           # final norm
        per_group = sum(self._layer_params(m, f) for m, f in self.group_pattern)
        n += per_group * self.num_groups
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts only routed experts)."""
        n = self.padded_vocab * self.d_model
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model
        n += self.d_model
        per_group = 0
        for m, f in self.group_pattern:
            p = self._layer_params(m, "none" if f == "moe" else f)
            if f == "moe":
                mult = 3 if self.gated_ffn else 2
                p += (self.num_experts_per_tok + self.num_shared_experts) * \
                    mult * self.d_model * self.moe_d_ff
                p += self.d_model * self.num_experts
            per_group += p
        n += per_group * self.num_groups
        return n

    def model_flops(self, tokens: int, *, training: bool) -> float:
        """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference."""
        mult = 6.0 if training else 2.0
        return mult * self.active_param_count() * tokens


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Produce a smoke-test-sized variant of a config (same family/pattern)."""
    pat = cfg.group_pattern
    small = dict(
        num_layers=len(pat) * overrides.pop("num_groups", 1),
        d_model=overrides.pop("d_model", 64),
        num_heads=overrides.pop("num_heads", 4),
        num_kv_heads=overrides.pop("num_kv_heads", min(cfg.num_kv_heads, 2)),
        head_dim=overrides.pop("head_dim", 16),
        d_ff=overrides.pop("d_ff", 128),
        vocab_size=overrides.pop("vocab_size", 512),
        num_experts=(overrides.pop("num_experts", 4) if cfg.num_experts else 0),
        moe_d_ff=(overrides.pop("moe_d_ff", 64) if cfg.num_experts else 0),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        ssm_d_inner=(overrides.pop("ssm_d_inner", 128) if cfg.ssm_d_inner else 0),
        ssm_state=(overrides.pop("ssm_state", 16) if cfg.ssm_state else 0),
        ssm_head_dim=(overrides.pop("ssm_head_dim", 32) if cfg.ssm_d_inner else 64),
        ssm_chunk=overrides.pop("ssm_chunk", 32),
        vision_seq=(overrides.pop("vision_seq", 16) if cfg.vision_seq else 0),
        sliding_window=(overrides.pop("sliding_window", 32)
                        if cfg.sliding_window else None),
        attn_dense_max_seq=overrides.pop("attn_dense_max_seq", 128),
        attn_chunk_q=overrides.pop("attn_chunk_q", 32),
        attn_chunk_kv=overrides.pop("attn_chunk_kv", 32),
        expected_params=0,
        name=cfg.name + "-smoke",
        remat=False,
        dtype=overrides.pop("dtype", "float32"),
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
