"""Data pipeline: synthetic LM token streams for training and Poisson request
workloads (with the paper's output-token distributions) for serving.

Training batches are generated deterministically from a seed (restart-safe:
the dataset index is part of the checkpoint ``extra`` metadata, so resuming
replays from the same position — exactly-once sample semantics without a
filesystem dataset).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.distributions import TokenDistribution
from repro.models.config import ModelConfig


class SyntheticLMDataset:
    """Zipf-distributed token sequences with structure (local n-gram
    correlations) so smoke-training shows a real falling loss."""

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.index = 0
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks ** 1.1)
        self._probs /= self._probs.sum()

    def _rng(self, index: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, index))

    def batch(self, index: Optional[int] = None) -> dict:
        idx = self.index if index is None else index
        rng = self._rng(idx)
        b, s, v = self.global_batch, self.seq_len, self.cfg.vocab_size
        base = rng.choice(v, size=(b, s + 1), p=self._probs)
        # inject determinism: every token at even position repeats previous
        # (learnable bigram structure)
        base[:, 2::2] = (base[:, 1:-1:2] * 7 + 13) % v
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        out = {"labels": labels}
        if self.cfg.embeddings_input:
            erng = self._rng(idx + 10 ** 9)
            out["embeds"] = erng.normal(
                0, 0.02, (b, s, self.cfg.d_model)).astype(np.float32)
            out["labels"] = labels % self.cfg.vocab_size
        else:
            out["tokens"] = tokens
        if self.cfg.vision_seq:
            irng = self._rng(idx + 2 * 10 ** 9)
            out["image_embeds"] = irng.normal(
                0, 0.02, (b, self.cfg.vision_seq, self.cfg.d_model)
            ).astype(np.float32)
        if index is None:
            self.index += 1
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.batch()


# ----------------------------------------------------------------------------
# Serving workload
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_tokens: np.ndarray        # int32 [prompt_len]
    target_output_tokens: int        # "user requirement" n_req (paper SIII)
    # filled by the engine:
    start_time: float = -1.0
    finish_time: float = -1.0
    generated: int = 0
    # re-entrant sessions (repro.core.sessions): -1/1/0.0 on
    # session-free streams (the historical defaults)
    session: int = -1                # session id (-1: not part of one)
    turn: int = 1                    # 1-based turn index within the session
    think: float = 0.0               # delay after the previous turn's finish

    @property
    def queue_wait(self) -> float:
        return self.start_time - self.arrival


def correlated_prompt_len(out_tokens: float, corr: float,
                          rng: np.random.Generator,
                          lo: int = 4, hi: int = 512) -> int:
    """Prompt length correlated with the output requirement: longer asks
    tend to come with longer prompts (log-linear, plus noise).  ``corr``
    scales the informative slope — the signal a prompt-feature length
    predictor (:class:`repro.core.predictors.PromptFeaturePredictor`) can
    actually learn from."""
    plen = corr * 10.0 * np.log1p(float(out_tokens)) + rng.normal(0.0, 2.0)
    return int(np.clip(round(plen), lo, hi))


def make_request_stream(num: int, lam: float, dist: TokenDistribution,
                        vocab: int, prompt_len_range=(8, 64),
                        seed: int = 0, prompt_len_corr: float = 0.0,
                        traffic=None, sessions=None):
    """Poisson arrivals + iid output-token requirements (the paper's model).

    ``prompt_len_corr=0`` (default) keeps prompt lengths independent of
    the output requirement — the historical stream, bit-identical to
    earlier seeds.  ``prompt_len_corr>0`` draws prompt lengths from
    :func:`correlated_prompt_len` instead, giving prompt-derived length
    predictors a real signal.

    ``traffic`` (a :mod:`repro.core.traffic` model, registry name or
    spec) modulates the arrival RATE: the stationary arrivals are drawn
    in the exact historical rng call order, then pushed through the
    model's time-rescaling warp — tokens and prompts are bit-identical
    with modulation on or off, and a null model (``None``, or any
    registered model at zero modulation) leaves the arrivals themselves
    bit-identical too.

    ``sessions`` (a :mod:`repro.core.sessions` model, registry name or
    spec) expands the ``num`` base requests into multi-turn sessions:
    the base stream above is drawn FIRST in the exact historical rng
    call order (turn-1 rows reuse it verbatim), then turns >= 2 draw
    their lengths/prompts from the salted session lanes — a null model
    (``None``, ``single``, or zero feedback) returns the identical
    session-free list.  Expanded arrivals are the lower bound ``base +
    cumulative think``; a session-aware driver re-enqueues each turn at
    its predecessor's finish + ``think``."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, num))
    if traffic is not None:
        from repro.core.traffic import traffic_from_spec
        arrivals = traffic_from_spec(traffic).warp(arrivals, seed)
    outs = dist.sample(rng, num)
    reqs = []
    for i in range(num):
        if prompt_len_corr:
            plen = correlated_prompt_len(outs[i], prompt_len_corr, rng)
        else:
            plen = int(rng.integers(*prompt_len_range))
        reqs.append(Request(
            rid=i, arrival=float(arrivals[i]),
            prompt_tokens=rng.integers(0, vocab, plen).astype(np.int32),
            target_output_tokens=int(max(outs[i], 1)),
        ))
    if sessions is None:
        return reqs
    from repro.core.sessions import (_PROMPT_LANE, _TOKENS_LANE,
                                     _session_rng, plan_sessions,
                                     session_from_spec)
    model = session_from_spec(sessions)
    if model.is_null:
        return reqs
    plan = plan_sessions(model, num, seed)
    trng = _session_rng(seed, _TOKENS_LANE)
    prng = _session_rng(seed, _PROMPT_LANE)
    extra_outs = dist.sample(trng, int((plan.turn >= 2).sum()))
    cs = np.cumsum(plan.think)
    out_reqs, j = [], 0
    for s in range(num):
        base = reqs[s]
        for t in range(int(plan.turns[s])):
            row = int(plan.offsets[s]) + t
            if t == 0:
                req = dataclasses.replace(
                    base, rid=row, session=s, turn=1, think=0.0)
            else:
                plen = int(prng.integers(*prompt_len_range))
                req = Request(
                    rid=row,
                    arrival=float(base.arrival + cs[row]
                                  - cs[plan.offsets[s]]),
                    prompt_tokens=prng.integers(0, vocab, plen)
                    .astype(np.int32),
                    target_output_tokens=int(max(extra_outs[j], 1)),
                    session=s, turn=t + 1,
                    think=float(plan.think[row]),
                )
                j += 1
            out_reqs.append(req)
    return out_reqs
