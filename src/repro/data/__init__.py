from repro.data.pipeline import (
    SyntheticLMDataset,
    make_request_stream,
    Request,
)

__all__ = ["SyntheticLMDataset", "make_request_stream", "Request"]
