"""Shared utilities: pytree helpers, HLO cost parsing, roofline math."""

from repro.utils.tree import (
    tree_map_with_path,
    tree_size_bytes,
    tree_num_params,
    tree_allclose,
)

__all__ = [
    "tree_map_with_path",
    "tree_size_bytes",
    "tree_num_params",
    "tree_allclose",
]
