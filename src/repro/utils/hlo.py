"""Trip-count-corrected HLO cost model.

``compiled.cost_analysis()`` counts the body of a ``while`` loop (the lowering
of ``lax.scan``) exactly ONCE, regardless of trip count (verified empirically
on jax 0.8.2).  Our models scan over layer groups to keep HLO compact — so the
framework carries its own HLO text parser that:

  * parses every computation and instruction (result shapes, opcode, operands,
    called computations),
  * recovers loop trip counts from ``backend_config={"known_trip_count":...}``,
  * walks the call graph from ENTRY multiplying per-iteration costs by trip
    counts (recursively, so nested scans — e.g. a KV-block scan inside the
    layer scan — are handled),
  * accounts FLOPs (dot/convolution exactly from shapes; elementwise ~1/elem),
    HBM bytes (operands + results per fusion/op, the same optimistic model
    XLA's own cost analysis uses), and collective *wire* bytes per mesh axis
    using ring-algorithm factors.

It is cross-validated in ``tests/test_hlo_cost.py`` against
``cost_analysis()`` on fully unrolled graphs.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

# ----------------------------------------------------------------------------
# Shape parsing
# ----------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e3m4": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return int(self.elements * _DTYPE_BYTES.get(self.dtype, 4))


def parse_shapes(text: str) -> list:
    """Parse all array shapes out of a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        dim_t = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append(Shape(dtype, dim_t))
    return out


# ----------------------------------------------------------------------------
# Instruction / computation parsing
# ----------------------------------------------------------------------------

_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<opcode>[\w\-]+)\((?P<args>.*)$"
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\((?P<params>.*?)\)\s*->")
_CALLS_BRACE_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_CALLS_SINGLE_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[0-9,\{\}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "all-to-all-start", "ragged-all-to-all",
    "collective-broadcast",
}

# pure data-movement / metadata ops: no flops, no HBM bytes charged
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "get-dimension-size",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "all-to-all-done", "async-done", "opt-barrier", "domain", "token",
    "send", "send-done", "recv", "recv-done", "custom-call",
}

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "exponential", "log",
    "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "compare", "select",
    "clamp", "exponential-minus-one", "log-plus-one", "atan2",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "erf", "cbrt",
}


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shapes: list           # result shapes
    operands: list         # operand instruction names
    called: list           # called computation names
    trip_count: Optional[int]
    attrs: str             # raw attribute text (for dims, groups)
    raw_operands: str = "" # verbatim text inside the op's parens


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict
    is_entry: bool = False


def parse_hlo_module(text: str) -> dict:
    """Parse HLO text into {computation_name: Computation}."""
    comps: dict = {}
    cur: Optional[Computation] = None
    for raw_line in text.splitlines():
        line = raw_line.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group("name"), {}, bool(m.group(1)))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group("opcode")
        # args run to end of line; split into operand part and attrs
        args = m.group("args")
        depth, idx = 1, 0
        for idx, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_text, attr_text = args[: idx], args[idx + 1:]
        called = []
        cm = _CALLS_BRACE_RE.search(attr_text)
        if cm:
            called = [c.strip().lstrip("%") for c in cm.group(1).split(",") if c.strip()]
        else:
            cm = _CALLS_SINGLE_RE.search(attr_text)
            if cm:
                called = [cm.group(1)]
        # while: body=..., condition=... appear as separate attrs
        if opcode == "while":
            called = []
            for key in ("condition", "body"):
                km = re.search(key + r"=%?([\w\.\-]+)", attr_text)
                if km:
                    called.append(km.group(1))
        tm = _TRIP_RE.search(attr_text)
        trip = int(tm.group(1)) if tm else None
        operands = _OPERAND_RE.findall(operand_text)
        instr = Instruction(
            name=m.group("name"),
            opcode=opcode,
            shapes=parse_shapes(m.group("type")),
            operands=operands,
            called=called,
            trip_count=trip,
            attrs=attr_text,
            raw_operands=operand_text,
        )
        cur.instructions[instr.name] = instr
    return comps


# ----------------------------------------------------------------------------
# Cost accounting
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveStat:
    opcode: str
    bytes_moved: float        # wire bytes per chip (ring model)
    payload_bytes: float      # raw operand/result payload bytes
    group_size: int
    stride: int               # stride between consecutive members (mesh axis id)
    count: float = 1.0


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.bytes_moved * c.count for c in self.collectives)

    @property
    def collective_payload_bytes(self) -> float:
        return sum(c.payload_bytes * c.count for c in self.collectives)

    def wire_bytes_by_stride(self) -> dict:
        out: dict = {}
        for c in self.collectives:
            out[c.stride] = out.get(c.stride, 0.0) + c.bytes_moved * c.count
        return out

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for c in other.collectives:
            self.collectives.append(
                dataclasses.replace(c, count=c.count * mult)
            )


def _feeds(comp: "Computation", src_name: str, dst_name: str,
           transparent=("convert", "bitcast", "copy"), depth: int = 8) -> bool:
    """True if dst is src or reachable from src through transparent ops."""
    frontier = {src_name}
    for _ in range(depth):
        if dst_name in frontier:
            return True
        nxt = set()
        for ins in comp.instructions.values():
            if ins.opcode in transparent and ins.operands \
                    and ins.operands[0] in frontier:
                nxt.add(ins.name)
        if not nxt:
            break
        frontier = nxt
    return dst_name in frontier


def _parse_dims(attrs: str, key: str) -> list:
    m = re.search(key + r"=\{([0-9,]*)\}", attrs)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _parse_replica_groups(attrs: str, opcode: str):
    """Return (group_size, stride). stride identifies the mesh axis."""
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        # iota format: [G,S]<=[dims...] — membership stride depends on the
        # transpose; for [G,S]<=[N] plain, members are contiguous (stride 1).
        dims = [int(x) for x in m.group(3).split(",")]
        stride = 1
        tm = re.search(r"<=\[[0-9,]+\]T\(([0-9,]+)\)", attrs)
        if tm and len(dims) > 1:
            perm = [int(x) for x in tm.group(1).split(",")]
            # members of a group vary over the *last* logical dim; its stride
            # in device space is the product of dims after it in device order.
            last = perm.index(len(dims) - 1) if (len(dims) - 1) in perm else len(dims) - 1
            stride = 1
            for d in dims[last + 1:]:
                stride *= d
        return g, stride
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        members = [int(x) for x in first.split(",") if x.strip()]
        if len(members) >= 2:
            return len(members), members[1] - members[0]
        return max(len(members), 1), 1
    return 1, 1


def _collective_cost(instr: Instruction) -> Optional[CollectiveStat]:
    op = instr.opcode.replace("-start", "")
    if op not in _COLLECTIVES:
        return None
    g, stride = _parse_replica_groups(instr.attrs, op)
    shapes = instr.shapes
    if not shapes:
        return None
    total = sum(s.bytes for s in shapes)
    if instr.opcode.endswith("-start") and len(shapes) >= 2:
        # async start result = (operand_alias, result, ...) — take result
        total = shapes[1].bytes
    ring = (g - 1) / g if g > 1 else 0.0
    if op == "all-reduce":
        wire = 2.0 * total * ring
    elif op in ("all-gather", "collective-broadcast"):
        wire = total * ring          # result bytes
    elif op == "reduce-scatter":
        wire = total * g * ring      # result is the scattered shard; operand = g*result
    elif op in ("all-to-all", "ragged-all-to-all"):
        wire = total * ring
    elif op == "collective-permute":
        wire = float(total)
    else:
        wire = float(total)
    return CollectiveStat(op, wire, float(total), g, stride)


class HloCostModel:
    """Walks the parsed module and produces trip-count-corrected costs."""

    def __init__(self, text: str):
        self.comps = parse_hlo_module(text)
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)
        self._memo: dict = {}

    def _instr_flops(self, comp: Computation, instr: Instruction) -> tuple:
        """Return (flops, transcendentals) for a single instruction
        (excluding called computations, which the caller recurses into —
        except fusions/dots which we handle here)."""
        op = instr.opcode
        if op == "dot":
            out_elems = sum(s.elements for s in instr.shapes)
            lhs_contract = _parse_dims(instr.attrs, "lhs_contracting_dims")
            lhs_name = instr.operands[0] if instr.operands else None
            k = 1
            lhs = comp.instructions.get(lhs_name)
            if lhs is not None and lhs.shapes:
                for d in lhs_contract:
                    if d < len(lhs.shapes[0].dims):
                        k *= lhs.shapes[0].dims[d]
            return 2.0 * out_elems * k, 0.0
        if op == "convolution":
            out_elems = sum(s.elements for s in instr.shapes)
            sz = re.search(r"window=\{size=([0-9x]+)", instr.attrs)
            window = 1
            if sz:
                for d in sz.group(1).split("x"):
                    window *= int(d)
            # depthwise vs dense: feature_group_count
            fg = re.search(r"feature_group_count=(\d+)", instr.attrs)
            fg = int(fg.group(1)) if fg else 1
            in_ch = 1
            lhs = comp.instructions.get(instr.operands[0]) if instr.operands else None
            if lhs is not None and lhs.shapes and len(lhs.shapes[0].dims) >= 2:
                # NCW/NCHW assumed: channels = dim 1
                in_ch = lhs.shapes[0].dims[1]
            return 2.0 * out_elems * window * (in_ch // max(fg, 1)), 0.0
        if op in ("exponential", "log", "tanh", "logistic", "power", "sine",
                  "cosine", "rsqrt", "sqrt", "erf", "exponential-minus-one",
                  "log-plus-one", "atan2", "cbrt"):
            n = sum(s.elements for s in instr.shapes)
            return 0.0, float(n)
        if op in _ELEMENTWISE_FLOP_OPS:
            return float(sum(s.elements for s in instr.shapes)), 0.0
        if op in ("reduce", "reduce-window"):
            in_elems = 0
            for oname in instr.operands:
                oi = comp.instructions.get(oname)
                if oi is not None and oi.shapes:
                    in_elems += oi.shapes[0].elements
            return float(in_elems), 0.0
        return 0.0, 0.0

    def _operand_bytes(self, comp: Computation, instr: Instruction,
                       index: int) -> float:
        oi = comp.instructions.get(instr.operands[index]) \
            if index < len(instr.operands) else None
        if oi is None or not oi.shapes:
            return 0.0
        return float(sum(s.bytes for s in oi.shapes))

    def _instr_bytes(self, comp: Computation, instr: Instruction) -> float:
        if instr.opcode in _FREE_OPS or instr.opcode.endswith("-done"):
            return 0.0
        op = instr.opcode
        out_b = float(sum(s.bytes for s in instr.shapes))
        if op == "convert":
            # pure dtype converts are CPU-backend artifacts: XLA:CPU upcasts
            # the whole bf16 graph to f32; on TPU the graph stays bf16 and
            # these ops do not exist. Charged zero (DESIGN.md §8).
            return 0.0
        # ops that touch only a slice of their big operand (TPU executes
        # these in place / as windowed DMAs; charging the full operand would
        # overcount the scanned layer stack by num_groups x):
        if op == "dynamic-slice":
            return 2.0 * out_b                      # read slice + write
        if op == "dynamic-update-slice":
            upd = self._operand_bytes(comp, instr, 1)
            return 2.0 * upd                        # read update + write region
        if op == "gather":
            return 2.0 * out_b + self._operand_bytes(comp, instr, 1)
        if op == "scatter":
            upd = self._operand_bytes(comp, instr, 2)
            idx = self._operand_bytes(comp, instr, 1)
            return 2.0 * upd + idx
        if op == "broadcast":
            return out_b + self._operand_bytes(comp, instr, 0)
        if op == "fusion":
            return self._fusion_bytes(comp, instr)
        total = out_b
        for i in range(len(instr.operands)):
            total += self._operand_bytes(comp, instr, i)
        return float(total)

    def _fusion_bytes(self, comp: Computation, instr: Instruction) -> float:
        """Fusion boundary traffic with slice-awareness: an operand consumed
        only by dynamic-slice/gather inside the fusion contributes the slice
        size; a root dynamic-update-slice contributes the update size (XLA
        performs loop-carried DUS in place)."""
        callee = self.comps.get(instr.called[0]) if instr.called else None
        if callee is None:
            total = float(sum(s.bytes for s in instr.shapes))
            for i in range(len(instr.operands)):
                total += self._operand_bytes(comp, instr, i)
            return total
        # parameter name -> index (from "parameter(N)" raw operand text)
        param_idx = {}
        for ins in callee.instructions.values():
            if ins.opcode == "parameter":
                nm = re.match(r"\s*(\d+)", ins.raw_operands)
                param_idx[ins.name] = int(nm.group(1)) if nm else None
        # consumer map; convert/bitcast/copy are layout/dtype plumbing that
        # TPU folds into the surrounding op -> trace through them.
        transparent = ("convert", "bitcast", "copy")
        all_consumers: dict = {}
        for ins in callee.instructions.values():
            for o in ins.operands:
                all_consumers.setdefault(o, []).append(ins)

        def terminal_consumers(name, depth=0):
            out = []
            for c in all_consumers.get(name, []):
                if c.opcode in transparent and depth < 8:
                    out.extend(terminal_consumers(c.name, depth + 1) or [c])
                else:
                    out.append(c)
            return out

        total = 0.0
        for pname, idx in param_idx.items():
            cons = terminal_consumers(pname)
            if not cons or all(c.opcode in transparent for c in cons):
                continue  # feeds the root only through converts: identity
            if cons and all(c.opcode in ("dynamic-slice", "gather")
                            for c in cons):
                total += sum(sum(s.bytes for s in c.shapes) for c in cons)
            elif cons and all(c.opcode in ("dynamic-update-slice", "scatter")
                              for c in cons):
                # parameter reaches in-place update ops only; if it is the
                # TARGET (operand 0 chain) there is no full-array read on
                # TPU. If it is the update/indices operand, charge that.
                for c in cons:
                    upd_i = 1 if c.opcode == "dynamic-update-slice" else 2
                    upd = callee.instructions.get(c.operands[upd_i]) \
                        if len(c.operands) > upd_i else None
                    feeds_target = _feeds(callee, pname, c.operands[0],
                                          transparent)
                    if not feeds_target and upd is not None and upd.shapes:
                        total += sum(s.bytes for s in upd.shapes)
            elif idx is not None and idx < len(instr.operands):
                total += self._operand_bytes(comp, instr, idx)
        # result side: root DUS (possibly behind convert/bitcast/copy
        # plumbing) writes only the update region in place
        def peel(ins, depth=0):
            while ins is not None and ins.opcode in transparent \
                    and ins.operands and depth < 8:
                ins = callee.instructions.get(ins.operands[0])
                depth += 1
            return ins

        root = None
        for ins in callee.instructions.values():
            root = ins   # last instruction is ROOT in printed HLO
        roots = [root] if root is not None else []
        if root is not None and root.opcode == "tuple":
            roots = [callee.instructions.get(o) for o in root.operands]
        out_total = 0.0
        for r in roots:
            r = peel(r)
            if r is None:
                continue
            if r.opcode == "parameter":
                continue  # identity / pure-convert fusion: no real traffic
            if r.opcode in ("dynamic-update-slice", "scatter"):
                upd_i = 1 if r.opcode == "dynamic-update-slice" else 2
                upd = callee.instructions.get(r.operands[upd_i]) \
                    if len(r.operands) > upd_i else None
                out_total += (sum(s.bytes for s in upd.shapes)
                              if upd is not None and upd.shapes else 0.0)
            else:
                out_total += float(sum(s.bytes for s in r.shapes))
        if not roots:
            out_total = float(sum(s.bytes for s in instr.shapes))
        return total + out_total

    def comp_cost(self, name: str, *, charge_bytes: bool = True) -> HloCost:
        key = (name, charge_bytes)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps[name]
        cost = HloCost()
        for instr in comp.instructions.values():
            col = _collective_cost(instr)
            if col is not None:
                cost.collectives.append(col)
                cost.bytes_accessed += self._instr_bytes(comp, instr) if charge_bytes else 0.0
                continue
            if instr.opcode == "while":
                trip = instr.trip_count if instr.trip_count else 1
                for callee in instr.called:
                    cost.add(self.comp_cost(callee, charge_bytes=charge_bytes), trip)
                continue
            if instr.opcode == "fusion":
                # flops: recurse (dots inside fusions), bytes: fusion boundary only
                for callee in instr.called:
                    sub = self.comp_cost(callee, charge_bytes=False)
                    cost.flops += sub.flops
                    cost.transcendentals += sub.transcendentals
                    for c in sub.collectives:
                        cost.collectives.append(c)
                if charge_bytes:
                    cost.bytes_accessed += self._instr_bytes(comp, instr)
                continue
            if instr.opcode in ("call", "conditional", "async-start", "map"):
                for callee in instr.called:
                    cost.add(self.comp_cost(callee, charge_bytes=charge_bytes))
                continue
            if instr.opcode in ("reduce", "sort", "scatter", "select-and-scatter",
                                "reduce-window"):
                f, t = self._instr_flops(comp, instr)
                cost.flops += f
                cost.transcendentals += t
                if charge_bytes:
                    cost.bytes_accessed += self._instr_bytes(comp, instr)
                continue
            f, t = self._instr_flops(comp, instr)
            cost.flops += f
            cost.transcendentals += t
            if charge_bytes:
                cost.bytes_accessed += self._instr_bytes(comp, instr)
        self._memo[key] = cost
        return cost

    def entry_cost(self) -> HloCost:
        if self.entry is None:
            return HloCost()
        return self.comp_cost(self.entry.name)


def analyze_hlo_text(text: str) -> HloCost:
    return HloCostModel(text).entry_cost()


def cost_summary(cost: HloCost) -> dict:
    by_stride = cost.wire_bytes_by_stride()
    by_op: dict = {}
    for c in cost.collectives:
        key = c.opcode
        by_op[key] = by_op.get(key, 0.0) + c.bytes_moved * c.count
    return {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "bytes_accessed": cost.bytes_accessed,
        "collective_wire_bytes": cost.collective_wire_bytes,
        "collective_payload_bytes": cost.collective_payload_bytes,
        "wire_bytes_by_stride": {str(k): v for k, v in by_stride.items()},
        "wire_bytes_by_op": by_op,
    }


# ----------------------------------------------------------------------------
# "Profiler": aggregate trip-count-corrected costs by jax op_name metadata
# (no wall clock on CPU — the lowered module is the profile).
# ----------------------------------------------------------------------------

_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _opname_bucket(attrs: str, depth: int = 3) -> str:
    m = _OPNAME_RE.search(attrs)
    if not m:
        return "<none>"
    name = m.group(1)
    # strip jit(...)/ prefix and keep a few trailing segments
    parts = [p for p in name.split("/") if p]
    tail = [p for p in parts if not p.startswith("jit(")]
    return "/".join(tail[-depth:]) if tail else name


def profile_by_opname(text: str, depth: int = 3, top: int = 25):
    """Returns list of (bucket, flops, bytes) sorted by bytes desc."""
    model = HloCostModel(text)
    agg: dict = {}

    def add(bucket, f, b):
        cur = agg.get(bucket, [0.0, 0.0])
        cur[0] += f
        cur[1] += b
        agg[bucket] = cur

    def walk(comp_name: str, mult: float):
        comp = model.comps[comp_name]
        for instr in comp.instructions.values():
            if instr.opcode == "while":
                trip = instr.trip_count or 1
                for c in instr.called:
                    walk(c, mult * trip)
                continue
            if instr.opcode in ("call", "conditional"):
                for c in instr.called:
                    walk(c, mult)
                continue
            b = model._instr_bytes(comp, instr) * mult
            f = 0.0
            if instr.opcode == "fusion":
                for c in instr.called:
                    sub = model.comp_cost(c, charge_bytes=False)
                    f += sub.flops * mult
            else:
                f = model._instr_flops(comp, instr)[0] * mult
            add(_opname_bucket(instr.attrs, depth), f, b)

    if model.entry is not None:
        walk(model.entry.name, 1.0)
    rows = sorted(((k, v[0], v[1]) for k, v in agg.items()),
                  key=lambda r: -r[2])
    return rows[:top]
