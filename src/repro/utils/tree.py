"""Pytree utilities used across the framework."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_map_with_path(fn, tree, *rest):
    """jax.tree.map with a '/'-joined string path as the first argument."""

    def _fmt(path) -> str:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, *leaves: fn(_fmt(path), *leaves), tree, *rest
    )


def tree_size_bytes(tree) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += size * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_num_params(tree) -> int:
    """Total number of scalar elements across all leaves."""
    return sum(int(np.prod(l.shape)) if l.shape else 1 for l in jax.tree.leaves(tree))


def tree_allclose(a, b, *, rtol=1e-5, atol=1e-6) -> bool:
    ok = True
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        ok = ok and np.allclose(np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol)
    return ok


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree
    )
