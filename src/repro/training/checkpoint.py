"""Sharded checkpointing with atomic commits, keep-last-k, async writes, and
mesh-resharding restore (fault-tolerance substrate).

Layout:
  <root>/step_<N>.tmp/...          (in-flight write)
  <root>/step_<N>/manifest.json    (commit marker: written LAST)
  <root>/step_<N>/leaf_<i>.npy     (one file per pytree leaf)

A checkpoint is valid iff its manifest exists, so a crash mid-write can never
yield a half-readable "latest" checkpoint. Restore takes target shardings
(possibly for a *different* mesh shape than the save) and ``jax.device_put``s
each leaf — this is the elastic-scaling path: lose a pod, rebuild a smaller
mesh, restore, continue.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    paths = []
    def fmt(p):
        out = []
        for k in p:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            elif hasattr(k, "name"):
                out.append(str(k.name))
            else:
                out.append(str(k))
        return "/".join(out)
    jax.tree_util.tree_map_with_path(lambda p, x: paths.append(fmt(p)), tree)
    return paths


class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3, async_write: bool = True):
        self.root = root
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict = None):
        """Snapshot to host memory synchronously; write to disk (optionally
        in the background)."""
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(l) for l in leaves]    # device -> host copy
        names = _leaf_paths(state)
        manifest = {
            "step": int(step),
            "leaves": [
                {"name": n, "file": f"leaf_{i}.npy",
                 "shape": list(l.shape), "dtype": str(l.dtype)}
                for i, (n, l) in enumerate(zip(names, host_leaves))
            ],
            "extra": extra or {},
        }
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, manifest),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, manifest)

    def _write(self, step: int, host_leaves, manifest):
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self):
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). shardings: matching pytree of NamedShardings for
        the *current* mesh (resharding is implicit via device_put)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.root)
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for meta, ref, shd in zip(manifest["leaves"], leaves, shard_leaves):
            arr = np.load(os.path.join(d, meta["file"]))
            assert list(arr.shape) == list(ref.shape), (meta["name"], arr.shape, ref.shape)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, out), step, manifest.get("extra", {})
