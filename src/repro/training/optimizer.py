"""Hand-rolled AdamW (no optax dependency) with sharded state.

Moments inherit the parameter shardings and are *additionally* sharded over
the data axis for large models via the FSDP rule set (ZeRO-style); dtype is
configurable (fp32 default, bf16 for the >=300B configs to fit v5e HBM).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # linear warmup then cosine decay
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 decay_mask=None):
    """Returns (new_params, new_state, metrics). decay_mask: pytree of bools
    (False = no weight decay, e.g. norms/biases); default decays ndim>=2."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, g, m, v, dm):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if dm:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_d = jax.tree.leaves(decay_mask)
    out = [upd(p, g, m, v, d) for p, g, m, v, d in
           zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
