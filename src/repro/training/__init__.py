from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.train_step import make_train_step, train_input_specs

__all__ = [
    "AdamWState", "adamw_init", "adamw_update",
    "make_train_step", "train_input_specs",
]
