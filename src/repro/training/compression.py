"""Gradient compression for cross-data-axis reduction (distributed trick).

int8 symmetric quantization with per-block scales and error feedback:
gradients are quantized *before* the data-parallel all-reduce (halving or
quartering DP wire bytes vs bf16/fp32), dequantized after, and the
quantization residual is carried into the next step (error feedback keeps
SGD/Adam convergence unbiased to first order).

Under GSPMD we express this as quantize -> psum-style mean across the data
axis -> dequantize inside the jitted step; XLA moves the small int8 tensors
across the wire instead of fp32. Exposed via ``TrainConfig.compress_grads``.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (q [N,BLOCK] int8, scale [N] f32)."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads, errors=None):
    """Quantize every leaf (adding carried error feedback first).

    Returns (qs, scales, new_errors): three pytrees congruent with grads."""
    flat_g, treedef = jax.tree.flatten(grads)
    if errors is None:
        flat_e = [jnp.zeros_like(g, jnp.float32) for g in flat_g]
    else:
        flat_e = jax.tree.leaves(errors)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s, g.shape)
        qs.append(q)
        scales.append(s)
        errs.append(g32 - deq)
    unf = treedef.unflatten
    return unf(qs), unf(scales), unf(errs)


def decompress_tree(qs, scales, shapes_like):
    flat_q = jax.tree.leaves(qs)
    flat_s = jax.tree.leaves(scales)
    flat_ref, treedef = jax.tree.flatten(shapes_like)
    out = [dequantize_int8(q, s, r.shape, jnp.float32)
           for q, s, r in zip(flat_q, flat_s, flat_ref)]
    return treedef.unflatten(out)
