"""Training step: loss, grad accumulation (microbatch scan), AdamW update.

The step is a pure function lowered by pjit; batch shards over (pod, data),
parameters/optimizer state follow the model's logical-axis shardings (FSDP
rules shard the embed dim + moments over data for the >=70B configs).
Compute/comm overlap comes from the microbatch ``lax.scan``: XLA's
latency-hiding scheduler overlaps each microbatch's reduce-scatter with the
next microbatch's compute.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.config import ModelConfig
from repro.models.model import forward
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    aux_loss_coef: float = 0.01
    z_loss_coef: float = 1e-4
    grad_accum_dtype: str = "float32"   # bf16 for the >=300B configs
    label_pad_id: int = -1


def cross_entropy(logits, labels, pad_id: int = -1):
    """Masked token-mean CE + z-loss term (fp32)."""
    logits = logits.astype(jnp.float32)
    mask = (labels != pad_id)
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return ce.sum() / denom, (logz ** 2 * mask).sum() / denom


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, ctx: ShardCtx = NULL_CTX):
    def loss_fn(params, batch):
        logits, aux = forward(
            cfg, params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cross_kv=batch.get("image_embeds"),
            ctx=ctx)
        ce, z2 = cross_entropy(logits, batch["labels"], tcfg.label_pad_id)
        loss = ce + tcfg.z_loss_coef * z2 + tcfg.aux_loss_coef * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    ctx: ShardCtx = NULL_CTX):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    loss_fn = make_loss_fn(cfg, tcfg, ctx)
    grad_fn = jax.grad(loss_fn, has_aux=True)
    m = cfg.num_microbatches

    def train_step(params, opt_state, batch):
        if m > 1:
            def micro(carry, mb):
                acc = carry
                g, aux = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, aux

            mb_batch = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(tcfg.grad_accum_dtype)),
                params)
            grads, auxes = lax.scan(micro, acc0, mb_batch)
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics_in = jax.tree.map(lambda x: x.mean(), auxes)
        else:
            grads, metrics_in = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.adamw)
        metrics = dict(metrics_in)
        metrics.update(opt_metrics)
        metrics["loss"] = metrics_in["ce"]
        return new_params, new_opt, metrics

    return train_step


def train_input_specs(cfg: ModelConfig, global_batch: int, seq_len: int):
    """Shapes (not arrays) of one training batch for lowering/dry-run."""
    specs = {
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.embeddings_input:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len), jnp.int32)
    if cfg.vision_seq:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.vision_seq, cfg.d_model), jnp.bfloat16)
    return specs
