"""Optimized-variant sweep: every (arch x shape) single-pod cell re-lowered
with the beyond-paper optimizations from EXPERIMENTS.md §Perf applied
globally (tag 'opt'):

  train:   microbatches=4 (per-device μb 4), ZeRO moments over data
  decode:  scatter cache update, unrolled decode, head-major (bhsd) cache
  prefill: grouped MoE dispatch (automatic for MoE archs)

Usage: PYTHONPATH=src python scripts/run_optimized_sweep.py
"""

import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
CELL = """
from repro.launch.dryrun import run_cell
import json, sys
arch, shape = sys.argv[1], sys.argv[2]
overrides = json.loads(sys.argv[3])
run_cell(arch, shape, "single", "results/dryrun", overrides=overrides, tag="opt")
"""


def main():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.configs import ARCH_IDS, SHAPE_IDS, SHAPES, get_config, \
        shape_applicable
    import json

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPE_IDS:
            if not shape_applicable(cfg, shape):
                continue
            out = os.path.join(ROOT, "results", "dryrun",
                               f"{arch}__{shape}__single__opt.json")
            if os.path.exists(out):
                rec = json.load(open(out))
                if rec.get("status") == "ok":
                    print(f"[skip] {arch} {shape}")
                    continue
            kind = SHAPES[shape]["kind"]
            if kind == "train":
                ov = {"num_microbatches": 4, "zero_moments": True}
            elif kind == "decode":
                ov = {"decode_cache_update": "scatter",
                      "decode_unroll_layers": True,
                      "cache_layout": "bhsd"}
            else:
                ov = {}
            env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
            r = subprocess.run(
                [sys.executable, "-c", CELL, arch, shape, json.dumps(ov)],
                env=env, cwd=ROOT, timeout=3000)
            if r.returncode != 0:
                print(f"[FAIL] {arch} {shape}")


if __name__ == "__main__":
    main()
