#!/usr/bin/env python
"""Docs gate (CI `docs` job): fail if the documentation drifted.

1. Internal links: every relative markdown link in README.md and
   docs/*.md must point at an existing file (http(s)/mailto and pure
   anchors are skipped; `path#anchor` checks only the path).
2. Policy coverage: every policy registered in ``repro.core.policies``
   must be mentioned in docs/equations.md (backtick-quoted registry name),
   so a new discipline cannot land undocumented.  The same check runs
   inside ``benchmarks.bench_batching_policies.registry_coverage``.
3. Predictor coverage: every length predictor registered in
   ``repro.core.predictors`` must be mentioned in docs/predictors.md
   (backtick-quoted registry name) — same rationale, same enforcement via
   ``registry_coverage``.
4. Router coverage: every fleet router registered in ``repro.core.fleet``
   must be mentioned in docs/fleet.md (backtick-quoted registry name).
5. Fault coverage: every fault model in ``repro.core.faults``
   (``default_faults()``, i.e. the registry plus the null model) must be
   mentioned in docs/faults.md (backtick-quoted registry name).
6. Session coverage: every session (feedback) model in
   ``repro.core.sessions`` must be mentioned in docs/sessions.md
   (backtick-quoted registry name).
7. Performance page: docs/performance.md must exist and keep documenting
   the PR 7 perf surface — the ``decode_attention_impl`` switch and its
   ModelConfig default, the ``compact_impl`` switch, ``shard_map``
   sweeps, and the ragged/dense kernel pair.

Run from the repo root: ``PYTHONPATH=src python scripts/check_docs.py``.
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    out = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def check_links() -> list:
    errors = []
    for path in doc_files():
        with open(path) as f:
            text = f.read()
        for target in LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                              f"-> {target}")
    return errors


def _check_registry_docs(registry: dict, doc_relpath: str,
                         kind: str) -> list:
    """Every key of ``registry`` must appear backtick-quoted in the given
    doc file — one rule for every registry the repo gates."""
    path = os.path.join(ROOT, doc_relpath)
    if not os.path.exists(path):
        return [f"{doc_relpath} is missing"]
    with open(path) as f:
        text = f.read()
    return [f"{doc_relpath}: registered {kind} `{name}` is not documented"
            for name in sorted(registry) if f"`{name}`" not in text]


def _src_on_path():
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def check_policy_docs() -> list:
    _src_on_path()
    from repro.core.policies import REGISTRY
    return _check_registry_docs(REGISTRY, os.path.join("docs",
                                                       "equations.md"),
                                "policy")


def check_predictor_docs() -> list:
    _src_on_path()
    from repro.core.predictors import PREDICTORS
    return _check_registry_docs(PREDICTORS, os.path.join("docs",
                                                         "predictors.md"),
                                "predictor")


def check_router_docs() -> list:
    _src_on_path()
    from repro.core.fleet import ROUTERS
    return _check_registry_docs(ROUTERS, os.path.join("docs", "fleet.md"),
                                "router")


def check_fault_docs() -> list:
    _src_on_path()
    from repro.core.faults import default_faults
    return _check_registry_docs(default_faults(),
                                os.path.join("docs", "faults.md"),
                                "fault model")


def check_traffic_docs() -> list:
    _src_on_path()
    from repro.core.traffic import TRAFFIC
    return _check_registry_docs(TRAFFIC, os.path.join("docs",
                                                      "traffic.md"),
                                "traffic model")


def check_session_docs() -> list:
    _src_on_path()
    from repro.core.sessions import SESSIONS
    return _check_registry_docs(SESSIONS, os.path.join("docs",
                                                       "sessions.md"),
                                "session model")


def check_memory_docs() -> list:
    """docs/memory.md must exist and keep documenting the PR 10 memory
    surface by name — the budget model, the tandem clock/oracle, the
    per-layer knobs and the analytic arm — so a rename cannot leave the
    page describing an API that no longer exists."""
    _src_on_path()
    import repro.core.memory as mem
    path = os.path.join(ROOT, "docs", "memory.md")
    if not os.path.exists(path):
        return ["docs/memory.md is missing"]
    with open(path) as f:
        text = f.read()
    required = [f"`{name}`" for name in mem.__all__]
    required += ["`memory=`", "`kv_budget`", "`tandem_bound`",
                 "`stage_split`", "`memory_budget`", "`kv_peak`",
                 "`blocked_batches`", "`deferred_requests`"]
    errors = [f"docs/memory.md: {tok} is not documented"
              for tok in required if tok not in text]
    # the public surface itself must not silently shrink
    for name in ("MemoryBudget", "TandemClock", "tandem_oracle"):
        if not hasattr(mem, name):
            errors.append(f"repro.core.memory lost `{name}`")
    return errors


def check_performance_docs() -> list:
    """docs/performance.md must exist and mention the tunable perf
    surface by name, so a rename or removal cannot leave the page
    describing switches that no longer exist."""
    _src_on_path()
    from repro.models.config import ModelConfig
    path = os.path.join(ROOT, "docs", "performance.md")
    if not os.path.exists(path):
        return ["docs/performance.md is missing"]
    with open(path) as f:
        text = f.read()
    required = ["`decode_attention_impl`", "`compact_impl`", "`shard_map`",
                "`ragged`", "`dense`",
                f"`{ModelConfig.decode_attention_impl}`"]
    return [f"docs/performance.md: {tok} is not documented"
            for tok in required if tok not in text]


def main() -> int:
    errors = (check_links() + check_policy_docs() + check_predictor_docs()
              + check_router_docs() + check_fault_docs()
              + check_traffic_docs() + check_session_docs()
              + check_memory_docs() + check_performance_docs())
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        files = len(doc_files())
        print(f"check_docs: OK ({files} files, links + policy/predictor/"
              f"router/fault/traffic/session coverage + memory page + "
              f"performance page)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
