"""Re-run the HLO cost model over cached .hlo.zst artifacts (no recompile).

Usage: PYTHONPATH=src python scripts/reanalyze.py [results/dryrun]
"""

import glob
import json
import os
import sys

import zstandard as zstd

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.utils.hlo import analyze_hlo_text, cost_summary  # noqa: E402


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for hpath in sorted(glob.glob(os.path.join(out_dir, "*.hlo.zst"))):
        jpath = hpath.replace(".hlo.zst", ".json")
        if not os.path.exists(jpath):
            continue
        rec = json.load(open(jpath))
        text = zstd.ZstdDecompressor().decompress(
            open(hpath, "rb").read()).decode()
        rec["hlo_cost"] = cost_summary(analyze_hlo_text(text))
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"reanalyzed {os.path.basename(jpath)}: "
              f"flops={rec['hlo_cost']['flops']:.3g} "
              f"bytes={rec['hlo_cost']['bytes_accessed']:.3g}")


if __name__ == "__main__":
    main()
